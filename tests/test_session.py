"""Experiment-layer tests: store/framework registries, RoundPayload
validation, StoreStats aggregation, and the multi-stage ``FederatedSession``
acceptance path — >=3 stages with interleaved SE requests asserting
(a) only impacted shards retrain per request, (b) per-stage coded-store bytes
match the single-stage (shim) path, and (c) every registered framework is
bit-identical to the deprecated ``FLSimulator.unlearn`` shim."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.stores.store import (RoundPayload, STORES, StoreStats,
                                    make_store)
from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.data import client_datasets_images, make_image_data
from repro.fl import FLSimulator
from repro.fl.experiment import (FRAMEWORKS, FederatedSession, RequestSchedule,
                                 ScenarioConfig, UnlearnContext,
                                 UnlearnFramework, UnlearnRequest,
                                 build_session, get_framework,
                                 register_framework, run_scenario, run_unlearn,
                                 train_stage)

FL_TINY = FLConfig(num_clients=10, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=3, retrain_ratio=2.0)


def _tiny_sim(seed=0):
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(FL_TINY.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
    return FLSimulator(cfg, FL_TINY, clients, task="image",
                       opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                               grad_clip=0.0),
                       local_batch=10, seed=seed)


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- registries
class TestRegistries:
    def test_builtin_stores_registered(self):
        assert {"full", "uncoded", "coded"} <= set(STORES)

    def test_make_store_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown store"):
            make_store("nope", {0: [0]})

    def test_builtin_frameworks_registered(self):
        assert {"SE", "SE-uncoded", "FE", "FR", "RR"} <= set(FRAMEWORKS)
        assert get_framework("SE").name == "SE"

    def test_get_framework_unknown_raises(self):
        with pytest.raises(ValueError, match="unknown unlearning framework"):
            get_framework("nope")

    def test_third_party_framework_is_a_plugin(self):
        """The registry makes a new strategy drop-in: register, dispatch by
        name through the same entry point the built-ins use."""
        @register_framework("NOOP-test")
        class NoopEraser(UnlearnFramework):
            def run(self, ctx: UnlearnContext):
                return dict(ctx.record.shard_models), 0.0
        try:
            sim = _tiny_sim()
            rec = train_stage(sim, store_kind="uncoded", rounds=1)
            victim = rec.plan.shard_clients[0][0]
            res = run_unlearn(sim, "NOOP-test", rec, [victim])
            assert res.framework == "NOOP-test"
            assert res.cost_units == 0.0
            assert res.impacted_shards == [0]
            _trees_equal(res.models[0], rec.shard_models[0])
        finally:
            FRAMEWORKS.pop("NOOP-test")


# ------------------------------------------------------------- round payload
class TestRoundPayload:
    def test_exactly_one_form(self):
        with pytest.raises(ValueError, match="exactly one"):
            RoundPayload(0, {0: [0]})
        with pytest.raises(ValueError, match="exactly one"):
            RoundPayload(0, {0: [0]}, client_params={0: {}},
                         stacked={0: {}})

    def test_flat_requires_row_spec(self):
        import jax.numpy as jnp
        with pytest.raises(ValueError, match="row_spec"):
            RoundPayload(0, {0: [0]}, flat={0: jnp.zeros((1, 4))})

    def test_flat_payload_has_no_client_trees(self):
        import jax.numpy as jnp
        p = RoundPayload.from_flat(0, {0: [0]}, {0: jnp.zeros((1, 4))},
                                   row_spec=object())
        with pytest.raises(ValueError, match="no per-client trees"):
            list(p.iter_client_trees())


# ---------------------------------------------------------------- StoreStats
class TestStoreStats:
    def test_merge_and_iadd(self):
        a = StoreStats(server_bytes=1, client_bytes=2, encode_flops=3,
                       decode_flops=4, comm_bytes_store=5,
                       comm_bytes_retrieve=6)
        b = StoreStats(server_bytes=10, client_bytes=20, encode_flops=30,
                       decode_flops=40, comm_bytes_store=50,
                       comm_bytes_retrieve=60)
        c = a + b                      # non-mutating
        assert (a.server_bytes, b.server_bytes) == (1, 10)
        assert c == StoreStats(11, 22, 33, 44, 55, 66)
        a += b                         # mutating accumulate
        assert a == c
        assert a.to_dict()["comm_bytes_retrieve"] == 66

    def test_snapshot_is_independent(self):
        a = StoreStats(server_bytes=7)
        s = a.snapshot()
        a.server_bytes = 99
        assert s.server_bytes == 7


# ----------------------------------------------------- multi-stage sessions
class TestMultiStageSession:
    N_STAGES = 3

    @pytest.fixture(scope="class")
    def scheduled(self):
        """Shim path (per-stage train_stage/unlearn) vs FederatedSession on
        identically-seeded sims, with an SE request interleaved after every
        stage."""
        sim_a, sim_b = _tiny_sim(), _tiny_sim()

        # --- reference: the single-stage API, stage by stage --------------
        records_a, unlearns_a, victims = [], [], []
        for k in range(self.N_STAGES):
            with pytest.warns(DeprecationWarning):
                rec = sim_a.train_stage(store_kind="coded")
            records_a.append(rec)
            victim = rec.plan.shard_clients[k % rec.plan.num_shards][0]
            victims.append(victim)
            stage_results = {}
            for i, r in enumerate(records_a):
                if victim in set(r.plan.clients):
                    with pytest.warns(DeprecationWarning):
                        stage_results[i] = sim_a.unlearn("SE", r, [victim],
                                                         rounds=2)
            unlearns_a.append(stage_results)

        # --- session: same schedule, driven end-to-end --------------------
        schedule = RequestSchedule()
        for k, victim in enumerate(victims):
            schedule.add(UnlearnRequest([victim], framework="SE",
                                        after_stage=k, rounds=2))
        session = FederatedSession(sim_b, store_kind="coded")
        report = session.run(self.N_STAGES, schedule=schedule)
        return records_a, unlearns_a, victims, session, report

    def test_runs_three_stages(self, scheduled):
        records_a, _, _, session, report = scheduled
        assert len(session.records) == self.N_STAGES
        assert len(report.stages) == self.N_STAGES
        for rec_a, rec_b in zip(records_a, session.records):
            assert rec_a.plan.shard_clients == rec_b.plan.shard_clients

    def test_stage_models_match_single_stage_path(self, scheduled):
        records_a, _, _, session, _ = scheduled
        for rec_a, rec_b in zip(records_a, session.records):
            for s in rec_a.shard_models:
                _trees_equal(rec_a.shard_models[s], rec_b.shard_models[s])

    def test_only_impacted_shards_retrain(self, scheduled):
        """(a) per served request: the impacted set is exactly one shard
        (single-victim requests), and every other shard's model is
        bit-identical to the trained stage model (isolation)."""
        _, _, _, session, report = scheduled
        served = [(st.stage, u) for st in report.stages for u in st.unlearn]
        assert served                         # schedule actually fired
        for stage, res in served:
            rec = session.records[stage]
            assert len(res.impacted_shards) == 1
            (shard,) = res.impacted_shards
            assert set(res.models) == set(rec.shard_models)
            for s, model in rec.shard_models.items():
                if s != shard:
                    _trees_equal(res.models[s], model)

    def test_cross_stage_isolation_targets_only_member_stages(self, scheduled):
        """Request k (served after stage k) dispatches to exactly the
        completed stages whose plan contains its victim — no other stage's
        report gains an entry."""
        _, _, victims, session, report = scheduled
        for i, st in enumerate(report.stages):
            expected = sum(
                1 for k in range(self.N_STAGES)
                if k >= i
                and victims[k] in set(session.records[i].plan.clients))
            assert len(st.unlearn) == expected

    def test_coded_bytes_match_single_stage_path(self, scheduled):
        """(b) per stage, the session's coded-store accounting equals the
        single-stage shim path."""
        records_a, _, _, session, report = scheduled
        for rec_a, st in zip(records_a, report.stages):
            assert rec_a.store.stats.client_bytes == st.store_stats.client_bytes
            assert rec_a.store.stats.encode_flops == st.store_stats.encode_flops
            assert rec_a.store.stats.server_bytes == st.store_stats.server_bytes

    def test_session_unlearn_matches_shim(self, scheduled):
        """(c on SE) the session-served models are bit-identical to the
        per-stage shim calls.  Requests are served in schedule order, so
        stage i's unlearn list is [request k for k >= i hitting stage i]."""
        _, unlearns_a, _, session, report = scheduled
        for i, st in enumerate(report.stages):
            expected = [unlearns_a[k][i] for k in range(self.N_STAGES)
                        if i in unlearns_a[k]]
            assert len(st.unlearn) == len(expected)
            for res_a, res_b in zip(expected, st.unlearn):
                assert res_a.impacted_shards == res_b.impacted_shards
                assert res_a.cost_units == res_b.cost_units
                for s in res_a.models:
                    _trees_equal(res_a.models[s], res_b.models[s])

    def test_report_json_roundtrip(self, scheduled):
        *_, report = scheduled
        d = json.loads(report.to_json())
        assert d["num_stages"] == self.N_STAGES
        assert len(d["stages"]) == self.N_STAGES
        assert d["total_cost_units"] == report.total_cost_units
        merged = report.store_stats
        assert merged.client_bytes == sum(
            s.store_stats.client_bytes for s in report.stages)
        assert d["store_stats"]["client_bytes"] == merged.client_bytes


# ------------------------------------------------------- session semantics
class TestSessionSemantics:
    @pytest.fixture(scope="class")
    def session(self):
        s = FederatedSession(_tiny_sim(), store_kind="uncoded", rounds=2)
        s.run_stage()
        return s

    def test_session_rounds_override_flows_to_unlearn(self, session):
        """Stages trained with rounds=2 must unlearn with 2 rounds too —
        the session default used to be dropped, making FE index history
        norms for rounds that never ran."""
        victim = session.records[0].plan.shard_clients[0][0]
        res = session.unlearn(UnlearnRequest([victim], framework="FE"))[0]
        retained = len(session.records[0].plan.clients) - 1
        retrain_ep = max(int(FL_TINY.local_epochs / FL_TINY.retrain_ratio), 1)
        assert res.cost_units == 2 * retained * retrain_ep

    def test_apply_replaces_shard_models(self, session):
        rec = session.records[0]
        victim = rec.plan.shard_clients[0][0]
        before = rec.shard_models[0]
        session.unlearn(UnlearnRequest([victim], framework="SE", apply=True))
        assert rec.shard_models[0] is not before
        leaves_a = jax.tree.leaves(before)
        leaves_b = jax.tree.leaves(rec.shard_models[0])
        assert any(not np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(leaves_a, leaves_b))

    def test_apply_rejects_federation_level_frameworks(self, session):
        victim = session.records[0].plan.shard_clients[0][0]
        with pytest.raises(ValueError, match="shard-level"):
            session.unlearn(UnlearnRequest([victim], framework="FR",
                                           apply=True, rounds=1))

    def test_explicit_out_of_range_stage_raises(self, session):
        victim = session.records[0].plan.shard_clients[0][0]
        with pytest.raises(ValueError, match="stage"):
            session.unlearn(UnlearnRequest([victim], stages=[5]))

    def test_stage_report_uses_session_local_index(self):
        """A session on a simulator that already trained stages still
        reports/routes by session-local index."""
        sim = _tiny_sim()
        train_stage(sim, store_kind="uncoded", rounds=1)   # mgr counter -> 1
        s = FederatedSession(sim, store_kind="uncoded", rounds=1)
        s.run_stage()
        assert s.report.stages[0].stage == 0
        assert s.report.stages[0].plan_stage == 1
        assert s.records[s.report.stages[0].stage] is s.records[0]


# ----------------------------------------------------- batched request serving
class TestBatchedRequests:
    """batch_requests=True merges the requests due after a stage: each
    impacted shard retrains once per batch (union-of-clients semantics) and
    the merged result equals one run_unlearn over the union."""

    def _schedule(self):
        return RequestSchedule([
            UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                           after_stage=0, rounds=2),
            UnlearnRequest(lambda p: [p.shard_clients[1][0]], framework="SE",
                           after_stage=0, rounds=2),
        ])

    def test_batch_merges_compatible_requests(self):
        session = FederatedSession(_tiny_sim(), store_kind="coded",
                                   batch_requests=True)
        report = session.run(1, schedule=self._schedule())
        (st,) = report.stages
        assert len(st.unlearn) == 1                 # merged: one serve
        assert st.unlearn[0].impacted_shards == [0, 1]

    def test_batched_equals_union_request(self):
        s_bat, s_ref = _tiny_sim(), _tiny_sim()
        session = FederatedSession(s_bat, store_kind="coded",
                                   batch_requests=True)
        report = session.run(1, schedule=self._schedule())
        res_bat = report.stages[0].unlearn[0]
        rec = train_stage(s_ref, store_kind="coded")
        victims = [rec.plan.shard_clients[0][0], rec.plan.shard_clients[1][0]]
        res_ref = run_unlearn(s_ref, "SE", rec, victims, rounds=2)
        assert res_bat.cost_units == res_ref.cost_units
        assert res_bat.impacted_shards == res_ref.impacted_shards
        for s in res_ref.models:
            for a, b in zip(jax.tree.leaves(res_ref.models[s]),
                            jax.tree.leaves(res_bat.models[s])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)

    def test_sequential_default_unchanged(self):
        session = FederatedSession(_tiny_sim(), store_kind="coded")
        report = session.run(1, schedule=self._schedule())
        assert len(report.stages[0].unlearn) == 2   # one serve per request

    def test_incompatible_options_stay_separate(self):
        schedule = RequestSchedule([
            UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                           after_stage=0, rounds=2),
            UnlearnRequest(lambda p: [p.shard_clients[1][0]], framework="SE",
                           after_stage=0, rounds=1),
        ])
        session = FederatedSession(_tiny_sim(), store_kind="coded",
                                   batch_requests=True)
        report = session.run(1, schedule=schedule)
        assert len(report.stages[0].unlearn) == 2   # rounds differ: no merge

    def test_unlearn_batch_requires_stage(self):
        session = FederatedSession(_tiny_sim(), batch_requests=True)
        with pytest.raises(RuntimeError, match="no completed stages"):
            session.unlearn_batch([UnlearnRequest([0])])

    def test_scenario_config_batches(self):
        cfg = ScenarioConfig(num_clients=8, clients_per_round=8, num_shards=2,
                             local_epochs=2, global_rounds=2,
                             samples_per_client=30, image_size=8, test_n=50,
                             engine="stage", batch_requests=True,
                             schedule=RequestSchedule([
                                 UnlearnRequest(
                                     lambda p: [p.shard_clients[0][0]],
                                     framework="SE", after_stage=0, rounds=1),
                                 UnlearnRequest(
                                     lambda p: [p.shard_clients[1][0]],
                                     framework="SE", after_stage=0, rounds=1),
                             ]))
        report = run_scenario(cfg)
        (st,) = report.stages
        assert len(st.unlearn) == 1
        assert st.unlearn[0].impacted_shards == [0, 1]


# ------------------------------------------------- request edge cases
class TestRequestEdgeCases:
    @pytest.fixture(scope="class")
    def session(self):
        s = FederatedSession(_tiny_sim(), store_kind="coded", rounds=2)
        s.run_stage()
        return s

    def test_duplicate_client_ids_dedupe(self, session):
        """Duplicate ids in one request are a retry, not a double-erasure:
        resolution dedupes (order-preserving) and the served models equal
        the unique request's bit-for-bit."""
        victim = session.records[0].plan.shard_clients[0][0]
        dup = UnlearnRequest([victim, victim, victim], framework="SE")
        assert dup.resolve_clients(session.records[0].plan) == [victim]
        res_dup = session.unlearn(dup)[0]
        res_one = session.unlearn(UnlearnRequest([victim], framework="SE"))[0]
        assert res_dup.cost_units == res_one.cost_units
        assert res_dup.impacted_shards == res_one.impacted_shards
        for s in res_one.models:
            _trees_equal(res_dup.models[s], res_one.models[s])

    def test_callable_resolving_empty_serves_nothing(self, session):
        before = sum(len(st.unlearn) for st in session.report.stages)
        results = session.unlearn(UnlearnRequest(lambda plan: [],
                                                 framework="SE"))
        assert results == []
        after = sum(len(st.unlearn) for st in session.report.stages)
        assert after == before                     # report untouched

    def test_apply_with_batched_serving(self):
        """apply=True survives the batch merge: the union-serve's models
        land in the stage record for every impacted shard."""
        session = FederatedSession(_tiny_sim(), store_kind="coded",
                                   batch_requests=True, rounds=2)
        rec = session.run_stage()
        before = {s: rec.shard_models[s] for s in rec.shard_models}
        schedule = RequestSchedule([
            UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                           after_stage=0, apply=True),
            UnlearnRequest(lambda p: [p.shard_clients[1][0]], framework="SE",
                           after_stage=0, apply=True),
        ])
        due = schedule.due(0)
        (res,) = session.unlearn_batch(due)
        assert res.impacted_shards == [0, 1]
        for s in (0, 1):
            assert rec.shard_models[s] is not before[s]
            _trees_equal(rec.shard_models[s], res.models[s])


# ------------------------------------------------- unserved-request loss
class TestUnservedRequests:
    def _session(self, **kw):
        return FederatedSession(_tiny_sim(), store_kind="uncoded", rounds=1,
                                **kw)

    def test_unserveable_request_warns(self):
        schedule = RequestSchedule([UnlearnRequest([0], after_stage=5,
                                                   rounds=1)])
        with pytest.warns(UserWarning, match="never served"):
            self._session().run(1, schedule=schedule)

    def test_strict_schedule_raises(self):
        schedule = RequestSchedule([UnlearnRequest([0], after_stage=5,
                                                   rounds=1)])
        with pytest.raises(ValueError, match="never served"):
            self._session(strict_schedule=True).run(1, schedule=schedule)

    def test_served_schedule_does_not_warn(self, recwarn):
        session = self._session(strict_schedule=True)
        schedule = RequestSchedule([UnlearnRequest(
            lambda p: [p.shard_clients[0][0]], after_stage=0, rounds=1)])
        report = session.run(1, schedule=schedule)
        assert sum(len(st.unlearn) for st in report.stages) == 1
        assert not [w for w in recwarn.list
                    if "never served" in str(w.message)]


# ---------------------------------------------- all frameworks, shim parity
class TestFrameworkShimParity:
    @pytest.fixture(scope="class")
    def fixture(self):
        sim = _tiny_sim()
        rec = train_stage(sim, store_kind="coded")
        return sim, rec

    @pytest.mark.parametrize("fw", ["SE", "FE", "FR", "RR"])
    def test_registry_matches_deprecated_unlearn(self, fixture, fw):
        """(c) every registered framework produces models bit-identical to
        the FLSimulator.unlearn shim on a fixed seed."""
        sim, rec = fixture
        victim = rec.plan.shard_clients[0][0]
        res_new = run_unlearn(sim, fw, rec, [victim], rounds=2)
        with pytest.warns(DeprecationWarning):
            res_old = sim.unlearn(fw, rec, [victim], rounds=2)
        assert res_old.impacted_shards == res_new.impacted_shards
        assert res_old.cost_units == res_new.cost_units
        assert set(res_old.models) == set(res_new.models)
        for s in res_old.models:
            _trees_equal(res_old.models[s], res_new.models[s])


# ------------------------------------------------------------ scenario runner
class TestScenarioRunner:
    def test_run_scenario_end_to_end(self):
        cfg = ScenarioConfig(num_clients=8, clients_per_round=8, num_shards=2,
                             local_epochs=2, global_rounds=2,
                             samples_per_client=30, image_size=8, test_n=50,
                             num_stages=2,
                             schedule=RequestSchedule([UnlearnRequest(
                                 lambda plan: [plan.shard_clients[0][0]],
                                 framework="SE", after_stage=1, rounds=1)]))
        report = run_scenario(cfg)
        assert len(report.stages) == 2
        served = [u for st in report.stages for u in st.unlearn]
        assert served and all(u.framework == "SE" for u in served)
        assert report.total_cost_units > 0
        json.loads(report.to_json())

    def test_build_session_store_kind(self):
        cfg = ScenarioConfig(num_clients=8, clients_per_round=8, num_shards=2,
                             local_epochs=2, global_rounds=2,
                             samples_per_client=30, image_size=8, test_n=50,
                             store="uncoded")
        session, test = build_session(cfg)
        assert session.store_kind == "uncoded"
        assert test[0].shape[0] == 50
