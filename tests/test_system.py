"""System-level behaviour tests: sharding policy, launch steps, roofline
parsing, end-to-end FedAvg semantics on a debug mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, FLConfig, OptimizerConfig, SHAPES,
                           get_config, reduce_for_smoke)
from repro.launch.shardings import (act_rules, needs_fsdp, param_rules,
                                    param_shardings)
from repro.launch.train import make_calibration_step, make_fedavg_step
from repro.models import abstract_params, init_params
from repro.models.params import spec_for
from repro.optim import init_optimizer
from repro.roofline.analysis import parse_collectives


class TestShardingPolicy:
    def test_spec_for_drops_nondivisible(self):
        import unittest.mock as mock
        fake = mock.Mock()
        fake.axis_names = ("data", "model")
        fake.devices = np.zeros((16, 16))
        spec = spec_for((49155, 1024), ("vocab", "embed"),
                        {"vocab": ("model",), "embed": ("data",)}, fake)
        assert spec[0] is None          # 49155 % 16 != 0 -> dropped
        assert spec[1] == "data"        # 1024 % 16 == 0

        spec = spec_for((24, 128), ("heads", "head_dim"),
                        {"heads": ("model",), "head_dim": ("model",)}, fake)
        assert spec[0] is None and spec[1] == "model"  # fallback to head_dim

    def test_multi_axis_candidate(self):
        import unittest.mock as mock
        fake = mock.Mock()
        fake.axis_names = ("pod", "data", "model")
        fake.devices = np.zeros((2, 16, 16))
        spec = spec_for((256, 4096), ("batch", "seq"),
                        {"batch": (("pod", "data"), "data")}, fake)
        assert spec[0] == ("pod", "data")
        # batch=1 can't shard at all
        spec = spec_for((1, 524288), ("batch", "kvseq"),
                        {"batch": (("pod", "data"), "data"),
                         "kvseq": (("data", "model"), "data", "model")}, fake)
        assert len(spec) == 2 and spec[0] is None and spec[1] == ("data", "model")

    def test_fsdp_policy(self):
        assert needs_fsdp(get_config("jamba-1.5-large-398b"), "decode")
        assert needs_fsdp(get_config("yi-6b"), "train")
        assert not needs_fsdp(get_config("yi-6b"), "decode")
        assert not needs_fsdp(get_config("whisper-tiny"), "train")

    @pytest.mark.parametrize("arch", ["olmo-1b", "granite-moe-1b-a400m"])
    def test_param_shardings_build(self, arch):
        """Sharding pytrees build for real meshes and match param structure."""
        cfg = get_config(arch)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = param_rules(cfg, "train", multi_pod=False)
        sh = param_shardings(cfg, mesh, rules)
        p_abs = abstract_params(cfg)
        assert jax.tree.structure(sh) == jax.tree.structure(p_abs)


class TestLaunchSteps:
    def test_fedavg_step_decreases_loss(self):
        cfg = reduce_for_smoke(get_config("olmo-1b"))
        fl = FLConfig(fl_clients_per_step=2, fl_local_steps=2)
        opt = OptimizerConfig(name="adamw", lr=5e-3)
        params = init_params(cfg, jax.random.key(0))
        state = (params, init_optimizer(opt, params))
        step = jax.jit(make_fedavg_step(cfg, fl, opt))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 32)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        losses = []
        for _ in range(8):
            state, mets = step(state, batch)
            losses.append(float(mets["loss"]))
        assert losses[-1] < losses[0], losses

    def test_calibration_step_rescales_to_history(self):
        cfg = reduce_for_smoke(get_config("olmo-1b"))
        fl = FLConfig(fl_clients_per_step=2, fl_local_steps=2)
        params = init_params(cfg, jax.random.key(0))
        cal = jax.jit(make_calibration_step(cfg, fl))
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 32)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        hist = jnp.asarray([0.5, 0.5], jnp.float32)
        new_params, mets = cal(params, batch, hist)
        from repro.core.unlearning import tree_norm, tree_sub
        delta = tree_norm(tree_sub(new_params, params))
        # mean of two deltas each rescaled to 0.5 -> total delta <= 0.5 + tol
        assert 0.05 < float(delta) < 0.75


class TestRooflineParser:
    HLO = """
  %ar = f32[1024,128]{1,0} all-reduce(f32[1024,128]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} %y), replica_groups=[2,16]<=[32], dimensions={0}
  %a2a = f32[64,64]{1,0} all-to-all(f32[64,64]{1,0} %z), replica_groups={{0,1,2,3,4,5,6,7}}
"""

    def test_parse_kinds_and_bytes(self):
        out = parse_collectives(self.HLO, num_devices=32)
        by = out["collective_bytes_by_kind"]
        assert out["collective_op_counts"]["all-reduce"] == 1
        assert out["collective_op_counts"]["all-gather"] == 1
        ar = 2 * 3 * 1024 * 128 * 4 * (32 // 4)       # 2(n-1)*b * groups
        assert by["all-reduce"] == pytest.approx(ar)
        ag = 15 * 256 * 4096 * 2 * (32 // 16)
        assert by["all-gather"] == pytest.approx(ag)
        assert out["collective_bytes_total"] > 0

    def test_ignores_non_collectives(self):
        out = parse_collectives("%m = f32[8,8]{1,0} dot(%a, %b)", 8)
        assert out["collective_bytes_total"] == 0


class TestSmokeRunConfigs:
    def test_all_arch_shape_combos_resolve(self):
        """Every (arch x shape) resolves to a config + policy without error."""
        from repro.launch.dryrun import resolve_config
        n_skip = 0
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                cfg, notes = resolve_config(arch, shape)
                if cfg is None:
                    n_skip += 1
        assert n_skip == 1  # only whisper long_500k
