"""Telemetry-layer tests: the no-op default and its overhead bound, span
nesting/threading/signatures, the metrics registry, the hash-chained audit
log (tamper detection + journal splice), Chrome-trace export validation,
and the acceptance anchors — two seeded service runs under the virtual
clock produce bit-identical span trees AND bit-identical audit-chain
heads, and a fault-injected read records injection + recovery telemetry.
"""
import dataclasses
import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.core.coding import CodingScheme
from repro.data import client_datasets_images, make_image_data
from repro.durability import Journal
from repro.faults import FaultPlan
from repro.fl import FLSimulator
from repro.fl.experiment import (FederatedSession, RequestSchedule,
                                 UnlearnRequest, train_stage)
from repro.service import (ServiceRequest, UnlearningService, VirtualClock,
                           single_device_placement)
from repro.stores.store import CodedStore, RoundPayload
from repro.telemetry import (AuditChainError, AuditLog, GENESIS, NULL_TRACER,
                             MetricsRegistry, Tracer, chain_hash, configure,
                             get_tracer, render_tree, set_tracer,
                             to_chrome_trace, validate_chrome_trace,
                             verify_chain, verify_journal, write_chrome_trace,
                             write_jsonl)

FL_TINY = FLConfig(num_clients=10, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=3, retrain_ratio=2.0)


def _tiny_sim(seed=0):
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(FL_TINY.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
    return FLSimulator(cfg, FL_TINY, clients, task="image",
                       opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                               grad_clip=0.0),
                       local_batch=10, seed=seed)


def _req(rid, t, clients=(0,), deadline=None, framework="SE"):
    return ServiceRequest(t=t, clients=tuple(clients), framework=framework,
                          deadline=deadline, rid=rid)


@pytest.fixture(autouse=True)
def _restore_default_tracer():
    """Every test leaves the process-wide tracer in its no-op default —
    other test modules must keep seeing unchanged (untraced) behavior."""
    yield
    set_tracer(NULL_TRACER)


# -------------------------------------------------------------------- tracer
class TestTracer:
    def test_default_is_noop(self):
        tr = get_tracer()
        assert tr is NULL_TRACER and not tr.enabled
        with tr.span("anything", label=1) as sp:
            sp.annotate(more=2)
        tr.event("instant", x=3)
        tr.metrics.counter("c").inc()
        tr.metrics.histogram("h").observe(1.0)
        assert tr.all_spans() == [] and tr.signature() == ""
        assert tr.metrics.snapshot() == {}
        assert tr.describe() == {"enabled": False}

    def test_configure_installs_and_restores(self):
        tr = configure(enabled=True)
        assert get_tracer() is tr and tr.enabled
        assert configure(enabled=False) is NULL_TRACER
        assert get_tracer() is NULL_TRACER

    def test_nesting_and_tree(self):
        tr = Tracer()
        with tr.span("outer", stage=0):
            with tr.span("inner", shard=1):
                pass
            tr.event("mark", hit=True)
        tree = tr.tree()
        assert [n["name"] for n in tree] == ["outer"]
        kids = tree[0]["children"]
        assert [n["name"] for n in kids] == ["inner", "mark"]
        assert kids[1]["kind"] == "event"
        assert tree[0]["labels"] == {"stage": 0}

    def test_signature_ignores_wall_time_but_not_labels(self):
        def forest(extra=None, sleep=0.0):
            tr = Tracer()
            with tr.span("a", k=1):
                if sleep:
                    time.sleep(sleep)
                with tr.span("b", **(extra or {})):
                    pass
            return tr.signature()

        assert forest(sleep=0.0) == forest(sleep=0.01)
        assert forest({"x": 1}) != forest({"x": 2})
        assert forest() != forest({"x": 1})

    def test_worker_thread_spans_are_order_independent_roots(self):
        def run(order):
            tr = Tracer()
            barrier = threading.Barrier(len(order))

            def worker(i):
                barrier.wait()
                with tr.span("job", idx=i):
                    time.sleep(0.001 * (i + 1))

            threads = [threading.Thread(target=worker, args=(i,))
                       for i in order]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            return tr

        a, b = run([0, 1, 2]), run([2, 1, 0])
        assert len(a.sorted_roots()) == 3
        assert a.signature() == b.signature()
        assert [r.labels["idx"] for r in a.sorted_roots()] == [0, 1, 2]

    def test_virtual_clock_dual_times(self):
        tr = Tracer()
        clock = VirtualClock()
        tr.attach_clock(clock)
        clock.advance_to(3.5)
        with tr.span("planned") as sp:
            clock.advance_to(7.25)
        assert sp.v0 == 3.5 and sp.v1 == 7.25
        assert sp.t1 >= sp.t0
        tr.detach_clock()
        with tr.span("unplanned") as sp2:
            pass
        assert sp2.v0 is None and sp2.v1 is None
        node = tr.tree()[0]
        assert node["v0"] == 3.5 and node["v1"] == 7.25


# ------------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_gauge_histogram_and_snapshot(self):
        m = MetricsRegistry()
        m.counter("reads", store="coded").inc()
        m.counter("reads", store="coded").inc(2)
        m.gauge("depth").set(4)
        m.gauge("depth").set(7)                      # last write wins
        for v in range(1, 101):
            m.histogram("lat_s", client=3).observe(v / 100)
        snap = m.snapshot()
        assert snap["counters"]["reads{store=coded}"] == 3
        assert snap["gauges"]["depth"] == 7
        h = snap["histograms"]["lat_s{client=3}"]
        assert h["count"] == 100 and h["p50"] == pytest.approx(0.505)
        assert m.histogram("lat_s", client=3).percentile(99) == \
            pytest.approx(0.9901)

    def test_absorb_is_idempotent_and_per_client_p99(self):
        m = MetricsRegistry()
        faults = {"injected": 5, "recovered_reads": 2, "note": "x"}
        m.absorb_faults(faults)
        m.absorb_faults(faults)                      # absorb twice: no double
        snap = m.snapshot()
        assert snap["gauges"]["faults.injected"] == 5
        assert "faults.note" not in snap["gauges"]
        for c, lat in ((0, 1.0), (0, 3.0), (7, 0.5)):
            m.histogram("service.client_latency_s", client=c).observe(lat)
        p99 = m.per_client_p99()
        assert set(p99) == {0, 7}
        assert p99[0] == pytest.approx(2.98) and p99[7] == pytest.approx(0.5)


# --------------------------------------------------------------------- audit
class TestAudit:
    def test_chain_append_verify_and_lookup(self):
        log = AuditLog()
        h1 = log.record("received", request_id="svc-0", clients=[7])
        h2 = log.record("committed", request_id="svc-0", batch_id=0)
        assert h2 == log.head != h1 != GENESIS
        assert log.verify() == h2
        assert log.kinds() == ["received", "committed"]
        assert [e["kind"] for e in log.events_of("svc-0")] == \
            ["received", "committed"]
        assert chain_hash(h1, log.records[1]["event"]) == h2

    def test_tampering_breaks_the_chain(self):
        log = AuditLog()
        for i in range(3):
            log.record("received", request_id=f"svc-{i}")
        tampered = [dict(r, event=dict(r["event"])) for r in log.records]
        tampered[1]["event"]["request_id"] = "svc-999"
        with pytest.raises(AuditChainError):
            verify_chain(tampered)
        with pytest.raises(AuditChainError):          # dropped record
            verify_chain(log.records[:1] + log.records[2:])
        with pytest.raises(AuditChainError):          # reordered
            verify_chain(list(reversed(log.records)))
        assert verify_chain(log.records) == log.head

    def test_journal_splice_extends_one_chain(self, tmp_path):
        path = str(tmp_path / "audit.journal")
        first = AuditLog(journal=Journal(path))
        first.record("received", request_id="svc-0", clients=[1])
        first.record("retrained", request_id="svc-0", shards=[0])

        resumed = AuditLog(journal=Journal(path))     # the resume path
        assert resumed.head == first.head and len(resumed) == 2
        resumed.record("committed", request_id="svc-0", batch_id=0)
        assert resumed.verify() == resumed.head != first.head
        assert verify_journal(Journal(path)) == resumed.head
        assert verify_journal(Journal(str(tmp_path / "empty.journal"))) \
            is None


# -------------------------------------------------------------------- export
class TestExport:
    def _forest(self):
        tr = Tracer()
        clock = VirtualClock()
        tr.attach_clock(clock)
        with tr.span("service.dispatch", batch=0):
            clock.advance_to(1.0)
            with tr.span("service.job", device=1, shard=0):
                pass
            tr.event("fault.inject", kind="slice_corruption")
        return tr

    def test_chrome_trace_validates_with_lanes(self, tmp_path):
        tr = self._forest()
        obj = to_chrome_trace(tr)
        assert validate_chrome_trace(obj) == []
        names = {e["name"] for e in obj["traceEvents"]}
        assert {"service.dispatch", "service.job", "fault.inject"} <= names
        lanes = {e["args"]["name"] for e in obj["traceEvents"]
                 if e["name"] == "thread_name"}
        assert "device-1" in lanes                # device-labeled span lane
        inst = [e for e in obj["traceEvents"] if e.get("ph") == "i"]
        assert inst and all(e.get("s") == "t" for e in inst)
        path = str(tmp_path / "trace.json")
        write_chrome_trace(tr, path)
        assert validate_chrome_trace(json.loads(open(path).read())) == []
        assert tr.trace_path == path

    def test_validator_catches_malformed(self):
        assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
        assert validate_chrome_trace(
            {"traceEvents": [{"ph": "??", "name": "x", "pid": 0, "tid": 0,
                              "ts": 0.0}]})

    def test_jsonl_and_tree_render(self, tmp_path):
        tr = self._forest()
        path = str(tmp_path / "spans.jsonl")
        write_jsonl(tr, path)
        lines = [json.loads(ln) for ln in open(path)]
        assert {ln["name"] for ln in lines} >= {"service.dispatch",
                                                "service.job"}
        text = render_tree(tr)
        assert "service.dispatch" in text and "service.job" in text


# --------------------------------------------------- integration (jit-heavy)
def _traced_service_run():
    """One seeded, fully traced workload: two stage-engine training stages,
    then a window-policy serve of three SE requests on one device."""
    tr = configure(enabled=True)
    sim = _tiny_sim(seed=0)
    session = FederatedSession(sim, store_kind="coded", engine="stage")
    session.run_stage()
    session.run_stage()
    svc = UnlearningService(session, policy="window",
                            policy_opts={"width": 0.5},
                            placement=single_device_placement())
    trace = [_req(0, 0.1, clients=(0,)), _req(1, 0.2, clients=(5,)),
             _req(2, 0.9, clients=(1,))]
    report = svc.serve(trace)
    return tr, svc, report


class TestIntegration:
    def test_seeded_runs_are_bit_identical(self):
        tr_a, svc_a, _ = _traced_service_run()
        sig_a, head_a, tree_a = tr_a.signature(), svc_a.audit.head, tr_a.tree()
        tr_b, svc_b, _ = _traced_service_run()
        assert tr_b.signature() == sig_a
        assert svc_b.audit.head == head_a
        assert tr_b.tree() == tree_a
        assert svc_b.audit.verify() == head_a
        kinds = svc_b.audit.kinds()
        assert kinds.count("received") == 3
        assert kinds.count("committed") == 3
        assert {"scheduled", "retrained"} <= set(kinds)

    def test_report_telemetry_section_gated_on_tracer(self):
        tr, svc, report = _traced_service_run()
        d = report.to_dict()
        assert d["telemetry"]["enabled"] is True
        assert d["telemetry"]["span_signature"] == tr.signature()
        assert d["telemetry"]["metrics"]["gauges"]["service.num_requests"] \
            == 3
        assert d["client_latency_p99_s"]
        required = {"session.stage", "stage.train", "xla.stage_program",
                    "store.put_stage", "store.read", "service.serve",
                    "service.plan", "service.dispatch", "service.job",
                    "unlearn.shard"}
        assert required <= set(tr.span_names())
        set_tracer(NULL_TRACER)
        assert "telemetry" not in report.to_dict()

    def test_session_audit_chain_spans_batched_unlearning(self, tmp_path):
        configure(enabled=True)
        session = FederatedSession(_tiny_sim(seed=0), store_kind="coded",
                                   engine="stage", batch_requests=True,
                                   checkpoint_every=1,
                                   checkpoint_dir=str(tmp_path))
        schedule = RequestSchedule([
            UnlearnRequest(lambda p, s=s: [p.shard_clients[s][0]],
                           framework="SE", after_stage=0)
            for s in (0, 1)])
        report = session.run(1, schedule=schedule)
        head = session.audit.verify()
        kinds = session.audit.kinds()
        assert kinds.count("received") == 2 and kinds.count("committed") == 2
        assert "retrained" in kinds
        assert verify_journal(session.checkpointer.journal) == head
        assert report.to_dict()["telemetry"]["enabled"] is True
        assert "durability.snapshot" in get_tracer().span_names()

    def test_chaos_read_records_injection_and_recovery(self):
        configure(enabled=True)
        c, s = 12, 4
        per = c // s
        shard_clients = {i: list(range(i * per, (i + 1) * per))
                         for i in range(s)}
        store = CodedStore(CodingScheme(num_shards=s, num_clients=c),
                           shard_clients)
        rng = np.random.default_rng(1)
        params = {cl: {"w": jnp.asarray(rng.standard_normal(5), jnp.float32)}
                  for cl in range(c)}
        store.put_round(RoundPayload.from_clients(0, shard_clients, params))
        store.attach_faults(
            FaultPlan(seed=7).add("slice_corruption", count=2))
        store.get_shard(0, 1)
        tr = get_tracer()
        reads = [sp for sp in tr.all_spans() if sp.name == "store.read"]
        assert reads and reads[-1].labels.get("recovered") is True
        assert reads[-1].labels.get("corrupted") == 2
        names = set(tr.span_names())
        assert names & {"fault.inject", "fault.recovery"}
        counters = tr.metrics.snapshot()["counters"]
        assert any(k.startswith("fault.") for k in counters)

    def test_null_tracer_overhead_bounded_below_2pct(self):
        # per-call cost of the disabled instrumentation path
        set_tracer(NULL_TRACER)
        tr = get_tracer()
        n = 100_000
        t0 = time.perf_counter()
        for _ in range(n):
            with tr.span("stage.train", engine="stage", shards=2) as sp:
                sp.annotate(stage=1)
        per_call = (time.perf_counter() - t0) / n

        # count the instrumentation sites one traced stage actually hits,
        # and the wall of the same stage untraced (warm jit)
        sim = _tiny_sim(seed=0)
        train_stage(sim, store_kind="coded", engine="stage")   # warm
        t0 = time.perf_counter()
        train_stage(sim, store_kind="coded", engine="stage")
        stage_wall = time.perf_counter() - t0
        configure(enabled=True)
        train_stage(sim, store_kind="coded", engine="stage")
        n_sites = len(get_tracer().all_spans())
        set_tracer(NULL_TRACER)

        # arithmetic bound: even charging 4 no-op calls per recorded span
        # (span + annotate + metrics + slack), disabled-tracer overhead
        # stays under 2% of the measured stage wall
        overhead = per_call * 4 * max(n_sites, 1)
        assert overhead < 0.02 * stage_wall, (
            f"null-tracer overhead {overhead * 1e6:.1f}us "
            f"({per_call * 1e9:.0f}ns/call x {n_sites} sites) exceeds 2% "
            f"of stage wall {stage_wall * 1e3:.1f}ms")
