"""Tiered-store tests: int8 quantization round-trip bounds (property-based),
the hot/warm/cold ladder's bit-stability, budget-driven eviction, the
acceptance anchors — an unlimited-budget ``TieredStore`` is bit-identical to
``CodedStore`` (models *and* shared ``StoreStats`` fields), and a fully
demoted session serves SE unlearning entirely from warm+cold within the
quantization bound — plus cold-tier corruption recovery through the robust
decoder and snapshot round-trips that carry cold-file pointers."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.data import client_datasets_images, make_image_data
from repro.durability import load_snapshot, save_snapshot
from repro.durability.session_state import _capture_store, _restore_store
from repro.faults import FaultPlan
from repro.fl import FLSimulator
from repro.fl.experiment import (FederatedSession, RequestSchedule,
                                 UnlearnRequest)
from repro.stores.store import STORES, RoundPayload, StoreStats, make_store
from repro.tiering import (EVICTION, TIER_ORDER, TIERS, TierEntry,
                           TieredStore, dequantize_int8, make_eviction,
                           quant_error_bound, quantize_int8)
from repro.tiering.tiers import cold_file_crc

FAULT_SEED = 20240

FL_TINY = FLConfig(num_clients=8, clients_per_round=8, num_shards=2,
                   local_epochs=1, global_rounds=3, retrain_ratio=2.0)


def _tiny_sim(seed=3):
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(FL_TINY.num_clients * 12, image_size=8, seed=0)
    clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
    return FLSimulator(cfg, FL_TINY, clients, task="image",
                       opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                               grad_clip=0.0),
                       local_batch=10, seed=seed)


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _unit_store(kind="tiered", c=12, s=4, rounds=1, seed=1, **opts):
    """Registry-built store over ``c`` clients / ``s`` shards with ``rounds``
    seeded rounds already flushed in (mirrors the fault-suite helper)."""
    per = c // s
    shard_clients = {i: list(range(i * per, (i + 1) * per))
                     for i in range(s)}
    store = make_store(kind, shard_clients, num_shards=s, num_clients=c,
                       **opts)
    rng = np.random.default_rng(seed)
    for rnd in range(rounds):
        params = {cl: {"w": jnp.asarray(rng.standard_normal(5), jnp.float32)}
                  for cl in range(c)}
        store.put_round(RoundPayload.from_clients(rnd, shard_clients, params))
    store.flush()
    return store


# ------------------------------------------------------------- quantization
class TestQuantization:
    @settings(max_examples=20, deadline=None)
    @given(c=st.integers(2, 24), p=st.integers(1, 64),
           log_mag=st.floats(-3.0, 3.0), seed=st.integers(0, 10_000))
    def test_round_trip_error_within_bound(self, c, p, log_mag, seed):
        rng = np.random.default_rng(seed)
        arr = jnp.asarray(rng.standard_normal((c, p)) * 10.0 ** log_mag,
                          jnp.float32)
        q, scales = quantize_int8(arr)
        back = np.asarray(dequantize_int8(q, scales), np.float64)
        err = np.abs(np.asarray(arr, np.float64) - back)
        # per-slice bound, and the global helper dominates every row
        assert (err.max(axis=1) <= scales * (0.5 + 127 * 1.2e-7) + 1e-12).all()
        assert err.max() <= quant_error_bound(scales) + 1e-12

    def test_zero_rows_are_exact(self):
        arr = jnp.zeros((3, 7), jnp.float32)
        q, scales = quantize_int8(arr)
        assert (np.asarray(q) == 0).all()
        assert (scales == 1.0).all()            # guarded against 0-division
        np.testing.assert_array_equal(np.asarray(dequantize_int8(q, scales)),
                                      np.zeros((3, 7), np.float32))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), bf16=st.booleans())
    def test_requantization_with_stored_scales_is_bit_exact(self, seed, bf16):
        """The lossy entry's invariant: dequantize → requantize with the SAME
        stored scales recovers q (and hence the dequantized value) exactly —
        repeated promote/demote cycles cannot drift."""
        rng = np.random.default_rng(seed)
        dt = jnp.bfloat16 if bf16 else jnp.float32
        arr = jnp.asarray(rng.standard_normal((6, 33)), dt)
        q1, scales = quantize_int8(arr)
        back = dequantize_int8(q1, scales, dtype=dt)
        q2, scales2 = quantize_int8(back, scales=scales)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_array_equal(scales, scales2)


# -------------------------------------------------------------- tier ladder
class TestTierLadder:
    def test_tiered_registered_in_stores(self):
        assert "tiered" in STORES
        assert isinstance(_unit_store(), TieredStore)

    def test_unlimited_budget_stays_hot(self):
        store = _unit_store()
        assert store.tier_of(0) == "hot"
        assert store.stats.tier_bytes["hot"] > 0
        assert store.stats.tier_bytes.get("warm", 0) == 0
        store.get_shard(0, 0)
        assert store.stats.tier_hits == {"hot": 1}
        assert store.stats.tier_misses == {}

    def test_zero_hot_budget_lands_warm_and_stays(self):
        store = _unit_store(hot_bytes=0)
        assert store.tier_of(0) == "warm"
        assert store.stats.tier_bytes["hot"] == 0
        assert store.stats.tier_evictions["hot"] >= 1
        store.get_shard(0, 0)
        # undersized hot budget must not promote (would churn forever)
        assert store.tier_of(0) == "warm"
        assert store.stats.tier_hits == {"warm": 1}
        assert store.stats.tier_misses == {"hot": 1}
        assert store.stats.tier_promotions == {}

    @pytest.mark.parametrize("slice_dtype", [None, "bfloat16"])
    def test_promote_demote_read_is_bit_stable(self, slice_dtype):
        """Once lossy, every read reconstructs the same bits — through warm,
        through cold, and through promote-back-to-hot cycles."""
        store = _unit_store(slice_dtype=slice_dtype)
        store.demote_all("warm")
        first = store.get_shard(0, 0)          # decodes warm, promotes hot
        assert store.tier_of(0) == "hot"
        store.demote_all("warm")
        _trees_equal(first, store.get_shard(0, 0))
        store.demote_all("cold")
        assert store.tier_of(0) == "cold"
        _trees_equal(first, store.get_shard(0, 0))
        assert store.stats.tier_promotions["hot"] == 3

    def test_cold_file_is_atomic_and_canonical(self):
        store = _unit_store()
        store.demote_all("cold")
        e = store._slices.entry(0)
        assert e.path is not None and os.path.exists(e.path)
        assert not any(f.endswith(".tmp") for f in os.listdir(store.cold_dir))
        assert cold_file_crc(e.path) == e.file_crc
        before = os.path.getmtime(e.path)
        store.get_shard(0, 0)                  # promote…
        store.demote_all("cold")               # …and demote again
        assert os.path.getmtime(e.path) == before   # file written exactly once

    def test_demote_all_rejects_unknown_tier(self):
        with pytest.raises(ValueError, match="unknown tier"):
            _unit_store().demote_all("lukewarm")

    def test_registries(self):
        assert tuple(TIER_ORDER) == ("hot", "warm", "cold")
        assert set(TIER_ORDER) <= set(TIERS)
        assert {"lru", "stage_age", "heat"} <= set(EVICTION)
        with pytest.raises(KeyError):
            make_eviction("nope")

    def test_eviction_policy_victim_choice(self):
        def entry(key, hits, last, stage):
            return TierEntry(key=key, shape=(2, 2), dtype=jnp.float32,
                             hits=hits, last_access=last, stage=stage)
        cands = [entry(0, hits=9, last=5, stage=2),
                 entry(1, hits=1, last=9, stage=0),
                 entry(2, hits=1, last=2, stage=1)]
        assert make_eviction("lru")(cands).key == 2          # oldest access
        assert make_eviction("stage_age")(cands).key == 1    # oldest birth
        # heat: fewest hits first, LRU tiebreak among the cold ones
        assert make_eviction("heat")(cands).key == 2

    def test_store_stats_tier_fields_merge_and_snapshot(self):
        a = StoreStats(tier_bytes={"hot": 10}, tier_hits={"hot": 2})
        b = StoreStats(tier_bytes={"hot": 5, "warm": 7},
                       tier_evictions={"hot": 1})
        tot = a + b
        assert tot.tier_bytes == {"hot": 15, "warm": 7}
        assert tot.tier_hits == {"hot": 2}
        assert tot.tier_evictions == {"hot": 1}
        snap = a.snapshot()
        snap.tier_bytes["hot"] = 999
        assert a.tier_bytes["hot"] == 10               # dicts are isolated


# ------------------------------------------------- session-level acceptance
def _schedule(rounds=1):
    return RequestSchedule([
        UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                       after_stage=0, rounds=rounds)])


def _run_session(store, store_options=None, seed=3):
    session = FederatedSession(_tiny_sim(seed), store_kind=store,
                               engine="stage",
                               store_options=store_options or {})
    report = session.run(1, schedule=_schedule())
    return session, report


@pytest.fixture(scope="module")
def coded_run():
    return _run_session("coded")


class TestUnlimitedBitIdentity:
    @pytest.fixture(scope="class")
    def tiered_run(self):
        return _run_session("tiered")

    def test_models_and_coded_slices_bit_identical(self, coded_run,
                                                   tiered_run):
        sess_c, _ = coded_run
        sess_t, _ = tiered_run
        for s in sess_c.records[0].shard_models:
            _trees_equal(sess_c.records[0].shard_models[s],
                         sess_t.records[0].shard_models[s])
        store_c, store_t = sess_c.records[0].store, sess_t.records[0].store
        store_c.flush(), store_t.flush()
        assert sorted(store_c._slices) == sorted(store_t._slices)
        for rnd in store_c._slices:
            np.testing.assert_array_equal(
                np.asarray(store_c._slices[rnd]),
                np.asarray(store_t._slices[rnd]))

    def test_unlearn_bit_identical(self, coded_run, tiered_run):
        (res_c,) = coded_run[1].stages[0].unlearn
        (res_t,) = tiered_run[1].stages[0].unlearn
        assert res_c.impacted_shards == res_t.impacted_shards
        assert res_c.cost_units == res_t.cost_units
        for s in res_c.models:
            _trees_equal(res_c.models[s], res_t.models[s])

    def test_shared_store_stats_byte_parity(self, coded_run, tiered_run):
        got_c = coded_run[1].store_stats.to_dict()
        got_t = tiered_run[1].store_stats.to_dict()
        tier_keys = {k for k in got_t if k.startswith("tier_")}
        for k in set(got_c) - tier_keys:
            assert got_c[k] == got_t[k], k
        assert got_t["tier_hits"].get("hot", 0) > 0
        assert got_t["tier_misses"] == {}

    def test_tier_metrics_surface_in_report(self, tiered_run):
        d = tiered_run[1].to_dict()
        assert d["store_stats"]["tier_bytes"]["hot"] > 0

    def test_tier_stats_fan_out_into_per_tier_gauges(self):
        from repro.telemetry.metrics import MetricsRegistry
        reg = MetricsRegistry()
        reg.absorb_store_stats(StoreStats(reads=3,
                                          tier_bytes={"hot": 8, "warm": 2},
                                          tier_hits={"cold": 1}), stage=0)
        gauges = reg.snapshot()["gauges"]
        tiered = {k: v for k, v in gauges.items() if "tier=" in k}
        assert any("store.tier_bytes" in k and "tier=hot" in k and v == 8
                   for k, v in tiered.items())
        assert any("store.tier_hits" in k and "tier=cold" in k and v == 1
                   for k, v in tiered.items())


class TestConstrainedServing:
    def test_se_unlearn_served_from_cold_within_quant_bound(self, coded_run,
                                                            tmp_path):
        """hot=warm=0: every stored round lives on disk, every decode is an
        int8 reconstruction — SE unlearning still lands within the
        quantization error envelope of the exact-store result."""
        sess, report = _run_session(
            "tiered", store_options=dict(hot_bytes=0, warm_bytes=0,
                                         offload_dir=str(tmp_path)))
        stats = report.store_stats
        assert set(stats.tier_hits) == {"cold"}
        assert stats.tier_bytes.get("hot", 0) == 0
        assert stats.tier_bytes.get("warm", 0) == 0
        assert stats.tier_hits["cold"] == stats.tier_misses["hot"] \
            == stats.tier_misses["warm"]
        # training never reads the store: shard models stay bit-identical
        sess_c, report_c = coded_run
        for s in sess_c.records[0].shard_models:
            _trees_equal(sess_c.records[0].shard_models[s],
                         sess.records[0].shard_models[s])
        (res_c,) = report_c.stages[0].unlearn
        (res_t,) = report.stages[0].unlearn
        assert res_c.impacted_shards == res_t.impacted_shards
        for s in res_c.models:
            diff = np.concatenate(
                [(np.asarray(x, np.float64) - np.asarray(y, np.float64)).ravel()
                 for x, y in zip(jax.tree.leaves(res_c.models[s]),
                                 jax.tree.leaves(res_t.models[s]))])
            ref = np.concatenate([np.asarray(x, np.float64).ravel()
                                  for x in jax.tree.leaves(res_c.models[s])])
            rel = np.linalg.norm(diff) / (np.linalg.norm(ref) + 1e-12)
            assert rel < 2e-2, rel                 # ~0.5% measured; bf16-order
            assert np.abs(diff).max() < 2e-2


# ------------------------------------------------------ cold-tier corruption
class TestColdCorruption:
    def test_cold_corrupt_recovers_and_is_accounted(self):
        clean = _unit_store(seed=7)
        clean.demote_all("cold")
        base = clean.get_shard(0, 0)
        store = _unit_store(seed=7)
        store.demote_all("cold")
        plan = FaultPlan(seed=FAULT_SEED).add("cold_corrupt", count=2,
                                              scale=10.0)
        store.attach_faults(plan)
        got = store.get_shard(0, 0)
        for cl in base:
            np.testing.assert_allclose(np.asarray(got[cl]["w"]),
                                       np.asarray(base[cl]["w"]), atol=1e-4)
        assert store.stats.corrupted_slices == 2
        assert store.stats.recovered_reads == 1
        assert plan.ledger.count("cold_corrupt") == 1
        assert plan.ledger.count("quorum_read") == 1

    def test_cold_corrupt_is_inert_for_hot_reads(self):
        store = _unit_store(seed=7)          # unlimited: stays hot
        plan = FaultPlan(seed=FAULT_SEED).add("cold_corrupt", count=2,
                                              scale=10.0)
        store.attach_faults(plan)
        store.get_shard(0, 0)
        assert store.stats.corrupted_slices == 0
        assert store.stats.recovered_reads == 0

    def test_quant_residue_is_not_flagged_as_corruption(self):
        """The widened lossy-read tolerance: an honest warm/cold round must
        decode clean — zero corrupted slices, zero recovery events."""
        store = _unit_store(seed=7)
        store.demote_all("cold")
        store.attach_faults(FaultPlan(seed=FAULT_SEED))   # empty plan
        store.get_shard(0, 0)
        assert store.stats.corrupted_slices == 0
        assert store.stats.recovered_reads == 0


# ------------------------------------------------------- snapshot round-trip
class TestTieredDurability:
    def _mixed_store(self, tmp_path):
        store = _unit_store(rounds=2, offload_dir=str(tmp_path))
        store.demote_all("cold")
        store.get_shard(1, 0)          # promote round 1 back to hot
        assert store.tier_of(0) == "cold" and store.tier_of(1) == "hot"
        return store

    def test_snapshot_round_trip_is_bit_identical(self, tmp_path):
        store = self._mixed_store(tmp_path)
        path = str(tmp_path / "store.ckpt")
        save_snapshot(path, _capture_store(store))
        back = _restore_store(load_snapshot(path))
        assert isinstance(back, TieredStore)
        assert back.budget == store.budget
        assert back.eviction == store.eviction
        for rnd in (0, 1):
            assert back.tier_of(rnd) == store.tier_of(rnd)
        assert back.stats.to_dict() == store.stats.to_dict()
        # round 0 reads come through the restored cold pointer on both sides
        for rnd in (0, 1):
            for s in range(4):
                want = store.get_shard(rnd, s)
                got = back.get_shard(rnd, s)
                for cl in want:
                    _trees_equal(want[cl], got[cl])

    def test_restore_rejects_corrupted_cold_file(self, tmp_path):
        store = self._mixed_store(tmp_path)
        state = _capture_store(store)
        cold = store._slices.entry(0).path
        with open(cold, "r+b") as f:
            f.seek(3)
            f.write(b"\xff\xff")
        with pytest.raises(IOError, match="crc"):
            _restore_store(state)

    def test_restore_rejects_missing_cold_file(self, tmp_path):
        store = self._mixed_store(tmp_path)
        state = _capture_store(store)
        os.remove(store._slices.entry(0).path)
        with pytest.raises(FileNotFoundError):
            _restore_store(state)
