"""Forgetting-verification suite (repro.verify) — calibration, exactness,
and the paper's acceptance ordering on a tiny CNN scenario.

The heavy fixture trains ONE victim federation pushed into the memorization
regime (high lr, many local epochs, few samples per client — both probes
measure memorization residue) and verifies SE and FE against the retrain
oracle and the no-unlearn baseline.  The asserted ordering is the paper's
prediction:

* the no-unlearn model scores strictly higher than the oracle on BOTH
  forgetting probes (shadow-MIA F1 and canary accuracy) — the probes can
  detect remembered data;
* the sharded frameworks land within a seeded tolerance of the oracle —
  unlearning is indistinguishable from never-trained;
* the oracle itself calibrates at the no-information rate (MIA F1 ~ 0.5
  under the balanced decision rule) and chance canary accuracy.

Everything but wall time is bit-reproducible under a fixed seed.
"""
import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import mia
from repro.fl.experiment.frameworks import UnlearnContext, run_unlearn
from repro.fl.experiment.scenario import ScenarioConfig, build_simulator
from repro.fl.experiment.stage import train_stage
from repro.fl.tasks import resolve_task
from repro.verify import (VERIFIERS, CanaryVerifier, ForgettingVerifier,
                          ShadowMIAVerifier, UtilityVerifier, get_verifier,
                          plant_canaries, predict_stage_victim,
                          resolve_verifiers, run_verification)
from repro.verify.report import CandidateScore, VerifyReport

# the tiny CNN victim scenario: memorization regime at CI scale
CFG = ScenarioConfig(task="classification", num_clients=8, clients_per_round=8,
                     num_shards=2, samples_per_client=32, image_size=10,
                     local_epochs=8, global_rounds=6, test_n=160, seed=3,
                     lr=0.3, noise=0.35, store="coded", engine="fused")
N_SHADOWS = 2
N_CANARIES = 12

# seeded tolerances: the run is deterministic, these bound the candidate-vs-
# oracle gap with headroom over the measured values (SE: mia .07 / canary
# .08; FE: mia .19 / canary .08)
TOL_MIA = 0.25
TOL_CANARY = 0.15
MARGIN_MIA = 0.05      # none must beat oracle by at least this much
MARGIN_CANARY = 0.10


@pytest.fixture(scope="module")
def report():
    return run_verification(CFG, frameworks=("SE", "FE"),
                            n_shadows=N_SHADOWS, n_canaries=N_CANARIES)


@pytest.fixture(scope="module")
def repeat_report():
    """Second independent run (SE only) for bit-reproducibility."""
    return run_verification(CFG, frameworks=("SE",),
                            n_shadows=N_SHADOWS, n_canaries=N_CANARIES)


# ---------------------------------------------------------------------------
# acceptance: the suite separates frameworks as the paper predicts
# ---------------------------------------------------------------------------

def test_probes_detect_remembered_data(report):
    none, oracle = report.candidate("none"), report.candidate("oracle")
    assert none.metrics["mia_f1"] > oracle.metrics["mia_f1"] + MARGIN_MIA
    assert (none.metrics["canary_acc"]
            > oracle.metrics["canary_acc"] + MARGIN_CANARY)


@pytest.mark.parametrize("fw", ["SE", "FE"])
def test_unlearned_indistinguishable_from_oracle(report, fw):
    assert report.gap(fw, "mia_f1") <= TOL_MIA
    assert report.gap(fw, "canary_acc") <= TOL_CANARY


def test_oracle_calibrates_at_no_information(report):
    oracle = report.candidate("oracle")
    # balanced decision rule -> no-information F1 ~ 0.5
    assert 0.3 <= oracle.metrics["mia_f1"] <= 0.65
    chance = oracle.metrics["canary_chance"]
    assert chance == pytest.approx(1 / 10)
    assert oracle.metrics["canary_acc"] <= chance + 0.15


def test_unlearning_preserves_retained_utility(report):
    none = report.candidate("none")
    for fw in ("SE", "FE", "oracle"):
        c = report.candidate(fw)
        assert c.metrics["retain_acc"] >= none.metrics["retain_acc"] - 0.25


def test_oracle_pays_the_full_retraining_bill(report):
    se, oracle = report.candidate("SE"), report.candidate("oracle")
    assert oracle.cost_units > se.cost_units
    assert report.candidate("none").cost_units == 0.0


def test_report_export_shape(report):
    d = report.to_dict()
    assert d["task"] == "classification" and d["seed"] == CFG.seed
    assert {c["name"] for c in d["candidates"]} == {"none", "SE", "FE",
                                                    "oracle"}
    assert set(d["gaps_to_oracle"]) == {"none", "SE", "FE"}
    assert "none" in d["pareto_front"]      # best forgetting-free utility
    assert report.to_json().startswith("{")


def test_bit_reproducible_under_fixed_seed(report, repeat_report):
    a, b = report.metrics_dict(), repeat_report.metrics_dict()
    for name in b:                           # repeat ran a candidate subset
        assert a[name] == b[name], f"candidate {name} not reproducible"


# ---------------------------------------------------------------------------
# oracle exactness: the framework output IS the manual retrain counterfactual
# ---------------------------------------------------------------------------

def test_oracle_matches_manual_retrain_loop():
    cfg = dataclasses.replace(CFG, local_epochs=3, global_rounds=3, test_n=80)
    sim, _ = build_simulator(cfg)
    record = train_stage(sim, store_kind=cfg.store, engine=cfg.engine)
    victim = record.plan.clients[0]
    res = run_unlearn(sim, "oracle", record, [victim])

    ctx = UnlearnContext(sim, record, [victim], sim.fl.global_rounds,
                         None, None)
    w0 = ctx.stage_init_model()
    for s in record.shard_models:
        if s not in res.impacted_shards:
            for a, b in zip(jax.tree.leaves(record.shard_models[s]),
                            jax.tree.leaves(res.models[s])):
                np.testing.assert_array_equal(a, b)
            continue
        retained = ctx.retained(s)
        assert victim not in retained
        g = len(record.round_globals[s]) - 1
        xs, ys = ctx.stack_client_data(retained)
        w = w0
        for _ in range(g):
            w = ctx.stacked_mean(ctx.local_train(w, xs, ys,
                                                 sim.fl.local_epochs))
        for a, b in zip(jax.tree.leaves(w), jax.tree.leaves(res.models[s])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


def test_oracle_registered_as_framework_alias():
    from repro.fl.experiment import FRAMEWORKS
    assert FRAMEWORKS["oracle"] is FRAMEWORKS["retrain-oracle"]
    assert FRAMEWORKS["oracle"].exact


# ---------------------------------------------------------------------------
# canary planting
# ---------------------------------------------------------------------------

def _client_data(task, n_clients=4, n=10, seed=0):
    rng = np.random.default_rng(seed)
    if task == "classification":
        mk = lambda: (rng.normal(size=(n, 6, 6, 1)).astype(np.float32),
                      rng.integers(0, 10, n).astype(np.int64))
    else:
        mk = lambda: (rng.integers(0, 30, (n, 12)).astype(np.int32),
                      rng.integers(0, 30, (n, 12)).astype(np.int32))
    return {c: mk() for c in range(n_clients)}


@pytest.mark.parametrize("task,cfg,chance", [
    ("classification", SimpleNamespace(num_classes=10), 0.1),
    ("generation", SimpleNamespace(vocab_size=30), 1 / 30),
])
def test_plant_canaries_replaces_first_k(task, cfg, chance):
    data = _client_data(task)
    before = {c: (x.copy(), y.copy()) for c, (x, y) in data.items()}
    spec = resolve_task(task)
    cx, cy, got_chance = plant_canaries(data, [1, 3], spec, cfg, n=4, seed=7)
    assert got_chance == pytest.approx(chance)
    assert cx.shape == (8,) + before[1][0].shape[1:]
    for v in (1, 3):
        x, y = data[v]
        bx, by = before[v]
        # replacement, not append: counts/shapes/dtypes unchanged
        assert x.shape == bx.shape and x.dtype == bx.dtype
        assert y.shape == by.shape and y.dtype == by.dtype
        assert not np.array_equal(x[:4], bx[:4])
        np.testing.assert_array_equal(x[4:], bx[4:])
    for c in (0, 2):                         # non-victims untouched
        np.testing.assert_array_equal(data[c][0], before[c][0])
        np.testing.assert_array_equal(data[c][1], before[c][1])


def test_plant_canaries_deterministic_and_per_victim_distinct():
    spec = resolve_task("classification")
    cfg = SimpleNamespace(num_classes=10)
    a = plant_canaries(_client_data("classification"), [1, 3], spec, cfg,
                       n=4, seed=7)
    b = plant_canaries(_client_data("classification"), [1, 3], spec, cfg,
                       n=4, seed=7)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    # different victims get different canaries (per-victim seed offset)
    assert not np.array_equal(a[0][:4], a[0][4:])


def test_plant_canaries_rejects_zero():
    with pytest.raises(ValueError, match="at least 1 canary"):
        plant_canaries(_client_data("classification"), [1],
                       resolve_task("classification"),
                       SimpleNamespace(num_classes=10), n=0, seed=0)


# ---------------------------------------------------------------------------
# task-routed MIA features (satellite: no raw task-string branching)
# ---------------------------------------------------------------------------

def test_classification_mia_features_formula():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(16, 10)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, 16))
    f = np.asarray(resolve_task("classification").mia_features(logits, y))
    ll = np.asarray(jax.nn.log_softmax(logits, -1))
    p = np.exp(ll)
    np.testing.assert_allclose(f[:, 0], -ll[np.arange(16), np.asarray(y)],
                               rtol=1e-5)
    np.testing.assert_allclose(f[:, 1], p.max(-1), rtol=1e-5)
    np.testing.assert_allclose(f[:, 2], -(p * ll).sum(-1), rtol=1e-5)


def test_generation_mia_features_sequence_mean():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(6, 12, 30)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 30, (6, 12)))
    f = np.asarray(resolve_task("generation").mia_features(logits, y))
    assert f.shape == (6, 3)
    ll = np.asarray(jax.nn.log_softmax(logits, -1))
    gold = np.take_along_axis(ll, np.asarray(y)[..., None], -1)[..., 0]
    np.testing.assert_allclose(f[:, 0], -gold.mean(-1), rtol=1e-5)


def test_mia_features_accept_spec_and_aliases():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(20, 8)).astype(np.float32)
    y = rng.integers(0, 4, 20).astype(np.int64)
    models = {0: None}
    predict = lambda _m, b: jnp.asarray(b["x"][:, :4])
    make_batch = lambda x, y: {"x": x, "y": y}
    outs = [mia._features(predict, models, make_batch, x, y, task)
            for task in ("classification", "image",
                         resolve_task("classification"))]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


# ---------------------------------------------------------------------------
# public predict surface (satellite: no private simulator attrs)
# ---------------------------------------------------------------------------

def test_predict_interface_public_surface():
    cfg = dataclasses.replace(CFG, local_epochs=1, global_rounds=1, test_n=40)
    sim, test = build_simulator(cfg)
    record = train_stage(sim, store_kind=cfg.store, engine=cfg.engine)
    iface = sim.predict_interface()
    assert iface.task is sim.task_spec
    x, y = test[0][:8], test[1][:8]
    lg = iface.ensemble_logits(record.shard_models, x, y)
    assert lg.shape[0] == 8 and lg.dtype == jnp.float32
    manual = sum(np.asarray(iface.predict(m, iface.make_batch(
        jnp.asarray(x), jnp.asarray(y)))) for m in
        record.shard_models.values()) / len(record.shard_models)
    np.testing.assert_allclose(np.asarray(lg), manual, rtol=1e-5, atol=1e-6)


def test_predict_stage_victim_matches_trained_plan():
    cfg = dataclasses.replace(CFG, local_epochs=1, global_rounds=1, test_n=40)
    victim = predict_stage_victim(cfg)
    sim, _ = build_simulator(cfg)
    record = train_stage(sim, store_kind=cfg.store, engine=cfg.engine)
    assert victim in record.plan.clients


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_verifier_registry():
    assert {"shadow-mia", "canary", "utility"} <= set(VERIFIERS)
    assert isinstance(get_verifier("canary"), CanaryVerifier)
    with pytest.raises(ValueError, match="unknown verifier"):
        get_verifier("nope")
    got = resolve_verifiers(["shadow-mia", UtilityVerifier,
                             CanaryVerifier(n_canaries=3)])
    assert isinstance(got[0], ShadowMIAVerifier)
    assert isinstance(got[1], UtilityVerifier)
    assert got[2].n_canaries == 3
    assert all(isinstance(v, ForgettingVerifier) for v in got)


def test_canary_score_before_plant_raises():
    with pytest.raises(RuntimeError, match="before plant"):
        CanaryVerifier().score(None, {})


# ---------------------------------------------------------------------------
# report mechanics (pure python)
# ---------------------------------------------------------------------------

def _mk_report():
    mk = lambda name, fw, cost, mia_f1, can, ret: CandidateScore(
        name, fw, 0.0, cost, {"mia_f1": mia_f1, "canary_acc": can,
                              "retain_acc": ret})
    return VerifyReport(
        task="classification", store="coded", seed=0, victims=[2],
        n_shadows=2, n_canaries=8, verifiers=["shadow-mia"],
        candidates=[mk("none", None, 0.0, 0.8, 0.6, 0.7),
                    mk("SE", "SE", 10.0, 0.5, 0.1, 0.68),
                    mk("slow", "FR", 99.0, 0.5, 0.1, 0.68),
                    mk("oracle", "oracle", 50.0, 0.5, 0.1, 0.7)])


def test_pareto_front_drops_dominated():
    front = _mk_report().pareto_front()
    # "slow" matches SE on every metric at 10x the cost -> dominated
    assert "slow" not in front
    assert {"SE", "oracle"} <= set(front)


def test_gap_and_candidate_lookup():
    rep = _mk_report()
    assert rep.gap("SE", "mia_f1") == pytest.approx(0.0)
    assert rep.gap("none", "canary_acc") == pytest.approx(0.5)
    with pytest.raises(KeyError, match="no candidate"):
        rep.candidate("missing")


def test_metrics_dict_excludes_walls():
    md = _mk_report().metrics_dict()
    assert "wall_s" not in md["SE"] and md["SE"]["cost_units"] == 10.0
